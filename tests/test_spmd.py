"""Distributed SPMD tests on a forced multi-device CPU mesh.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the CI
``spmd`` job does).  When the module is imported standalone it forces
the flag itself; inside a full-suite run where jax already initialized
a single-device backend, everything here skips.

What must hold (the acceptance criteria of the SPMD execution layer):
  * a train step sharded over ("pod","data","model") matches the
    single-device step within bf16-accumulation tolerance;
  * the continuous-batching engine produces *identical* token streams
    sharded and solo (greedy decode: reduction-order noise must never
    flip an argmax on this workload);
  * N:M-compressed cross-pod gradient sync stays within tolerance of
    dense sync, and its error feedback telescopes exactly;
  * N:M groups are never split by any resolved sharding, and the rules
    refuse to emit group-splitting specs;
  * checkpoints reshard: save on 8 devices, restore on 1, and back.
"""

import sys

if "jax" not in sys.modules:  # standalone: force before backend init
    from repro.launch.spmd import force_host_devices
    force_host_devices(8)

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

jax.config.update("jax_platform_name", "cpu")

if jax.device_count() < 8:
    pytest.skip(
        "needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
        allow_module_level=True)

from repro.configs import get_arch
from repro.core.sparsity import SparsityConfig
from repro.data import synthetic as D
from repro.launch import spmd
from repro.optim import sgd
from repro.optim import compress as C
from repro.sharding import rules as R
from repro.train import step as ST
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import train_steps

ARCH = get_arch("qwen3-8b")
CFG = ARCH.smoke
SP = SparsityConfig(n=2, m=8, method="bdwp")
OPT = sgd.SGDConfig(lr=0.1, total_steps=8)


@pytest.fixture(scope="module")
def mesh8():
    return spmd.make_spmd_mesh("pod,data,model")


@pytest.fixture(scope="module")
def mesh1():
    return spmd.single_device_mesh()


def _run_train(mesh, steps=3, compress=False):
    use_c = compress and "pod" in mesh.axis_names
    bundle = ST.build_lm_train(CFG, mesh, SP, OPT, donate=False,
                               compress=use_c)
    state = ST.init_train_state(jax.random.PRNGKey(0), CFG, compress=use_c,
                                sp_cfg=SP, mesh=mesh)
    state = jax.device_put(state, bundle.state_shardings)
    sh = {k: NamedSharding(mesh, ps) for k, ps in bundle.input_pspecs.items()}
    stream = D.lm_stream(CFG.vocab, 8, 32, shardings=sh, seed=0)
    state, hist = train_steps(bundle, state, stream, steps)
    return state, [float(m["loss"]) for m in hist]


def _host(tree):
    return [np.asarray(x) for x in jax.tree.leaves(tree)]


class TestMeshSpec:
    def test_auto_factoring(self):
        assert spmd.parse_mesh_spec("pod,data,model", 8) == \
            {"pod": 2, "data": 2, "model": 2}
        assert spmd.parse_mesh_spec("pod,data,model", 4) == \
            {"pod": 1, "data": 2, "model": 2}
        assert spmd.parse_mesh_spec("data,model", 1) == \
            {"data": 1, "model": 1}

    def test_explicit_and_mixed(self):
        assert spmd.parse_mesh_spec("pod=2,data=2,model=2", 8) == \
            {"pod": 2, "data": 2, "model": 2}
        assert spmd.parse_mesh_spec("pod=4,data,model", 8) == \
            {"pod": 4, "data": 1, "model": 2}

    def test_indivisible_raises(self):
        with pytest.raises(ValueError):
            spmd.parse_mesh_spec("pod=3,data,model", 8)

    def test_real_devices(self, mesh8):
        assert mesh8.devices.size == 8
        assert mesh8.axis_names == ("pod", "data", "model")
        assert all(s > 1 for s in mesh8.shape.values())


class TestTrainParity:
    def test_sharded_train_step_matches_single_device(self, mesh8, mesh1):
        s8, l8 = _run_train(mesh8)
        s1, l1 = _run_train(mesh1)
        np.testing.assert_allclose(l8, l1, atol=2e-3)
        for a, b in zip(_host(s8["master"]), _host(s1["master"])):
            np.testing.assert_allclose(a, b, atol=1e-3)

    def test_compressed_sync_parity(self, mesh8):
        """--compress (N:M cross-pod sync + error feedback) must track
        the dense-sync trajectory on the same mesh."""
        sd, ld = _run_train(mesh8, compress=False)
        sc, lc = _run_train(mesh8, compress=True)
        assert "err" in sc  # error-feedback state actually carried
        np.testing.assert_allclose(lc, ld, rtol=5e-3)
        for a, b in zip(_host(sc["master"]), _host(sd["master"])):
            np.testing.assert_allclose(a, b, atol=5e-2)

    def test_error_feedback_telescopes(self, mesh8):
        """Per pod p: decoded_t + e_t == g_t + e_{t-1} exactly (the fused
        kernel folds the bf16 wire rounding into the residual), so the
        pod-mean output telescopes: sum_t out_t + mean_p(e_T) ==
        sum_t mean_p(g_t) to fp32 precision — compression is lossless in
        accumulation even with per-pod DISTINCT gradients.  Ragged leaves
        (the (3,) bias) ride the dense pod mean and telescope trivially."""
        n_pods = mesh8.shape["pod"]
        key = jax.random.PRNGKey(3)
        grads = {"blk": {
            "w": jax.random.normal(key, (n_pods, 8, 8), jnp.float32),
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   (n_pods, 3), jnp.float32)}}
        pspecs = {"blk": {"w": P(), "b": P()}}
        master = {"blk": {"w": jnp.zeros((8, 8)), "b": jnp.zeros((3,))}}
        cfg = C.GradCompressConfig(n=SP.n, m=SP.m, bucket_elems=32)
        width = C.err_state_elems(master, SP.m, mesh8, pspecs)
        # replicated leaves -> every intra-pod device carries the whole
        # 64-elem slab: the EF state is S identical device slabs wide
        assert width == 64 * C.slab_shards(mesh8)
        err = jnp.zeros((n_pods, width), jnp.float32)
        acc = {"blk": {"w": jnp.zeros((8, 8)), "b": jnp.zeros((3,))}}
        sync = jax.jit(lambda g, e: C.cross_pod_sync(
            g, e, mesh8, pspecs, cfg))
        for t in range(4):
            g_t = jax.tree.map(lambda g, s=0.5 ** t: g * s, grads)
            out, err = sync(g_t, err)
            acc = jax.tree.map(jnp.add, acc, out)
        # fold the residual back in: pod-mean of the first device slab
        # (its duplicates are bitwise identical — deterministic top-k)
        err_slabs = np.asarray(err).mean(0).reshape(-1, 64)
        np.testing.assert_array_equal(err_slabs,
                                      np.broadcast_to(err_slabs[:1],
                                                      err_slabs.shape))
        acc["blk"]["w"] = acc["blk"]["w"] + err_slabs[0].reshape(8, 8)
        total = jax.tree.map(
            lambda g: g.mean(0) * sum(0.5 ** t for t in range(4)), grads)
        for a, b in zip(_host(acc), _host(total)):
            np.testing.assert_allclose(a, b, atol=1e-5)

    def test_bucket_split_refusal(self):
        with pytest.raises(ValueError, match="M-group"):
            C.GradCompressConfig(n=2, m=8, bucket_elems=20)
        with pytest.raises(ValueError, match="M-group"):
            C.plan_buckets(64, 12, 8)


class TestServeParity:
    def _run_engine(self, params, mesh, idx_bits=None):
        from repro.serve import ServeConfig, ServeEngine
        sc = ServeConfig(n_slots=4, max_len=32, prompt_bucket=12,
                         packed=True, idx_bits=idx_bits)
        eng = ServeEngine(params, CFG, SP, sc, mesh=mesh)
        rng = np.random.default_rng(3)
        for length in (4, 7, 11, 5, 9):
            eng.submit(rng.integers(0, CFG.vocab, length).tolist(),
                       max_new_tokens=8)
        return eng.run()

    def test_sharded_engine_decode_matches_solo(self, mesh8):
        from repro.models import transformer_lm as T
        params, _ = T.init(jax.random.PRNGKey(0), CFG)
        params = jax.tree.map(lambda w: w.astype(jnp.bfloat16), params)
        solo = self._run_engine(params, None)
        sharded = self._run_engine(params, mesh8)
        assert solo == sharded

    def test_sharded_u4_decode_matches_solo_u8(self, mesh8):
        """The fused u4 decode under GSPMD (TP-sharded index planes, the
        default store at m=8) streams the exact tokens of the solo
        byte-wide path — cross-format AND cross-mesh in one A/B; with
        test_sharded_engine_decode_matches_solo (u4 solo vs u4 sharded)
        this pins all four format/mesh corners to one stream."""
        from repro.models import transformer_lm as T
        params, _ = T.init(jax.random.PRNGKey(0), CFG)
        params = jax.tree.map(lambda w: w.astype(jnp.bfloat16), params)
        solo_u8 = self._run_engine(params, None, idx_bits=8)
        sharded_u4 = self._run_engine(params, mesh8, idx_bits=4)
        assert solo_u8 == sharded_u4

    def test_sharded_moe_mla_engine_matches_solo(self, mesh8):
        """deepseek smoke: MLA + MoE + unstacked prelude cache.  Guards
        the grouped-routing dispatch gather, which the partitioner
        miscompiles when fed from a concat-padded (unevenly sharded)
        token axis — models/moe._slot_gather uses an OOB-fill gather
        instead."""
        from repro.models import transformer_lm as T
        cfg = get_arch("deepseek-v2-lite-16b").smoke
        params, _ = T.init(jax.random.PRNGKey(0), cfg)
        params = jax.tree.map(lambda w: w.astype(jnp.bfloat16), params)
        from repro.serve import ServeConfig, ServeEngine
        sc = ServeConfig(n_slots=4, max_len=24, prompt_bucket=8)
        outs = []
        for mesh in (None, mesh8):
            eng = ServeEngine(params, cfg, SP, sc, mesh=mesh)
            rng = np.random.default_rng(5)
            for length in (3, 6, 8):
                eng.submit(rng.integers(0, cfg.vocab, length).tolist(),
                           max_new_tokens=6)
            outs.append(eng.run())
        assert outs[0] == outs[1]


class TestNMGroupInvariant:
    def test_resolved_train_shardings_unsplit(self, mesh8):
        bundle = ST.build_lm_train(CFG, mesh8, SP, OPT, donate=False)
        from repro.models import transformer_lm as T
        aparams, _ = T.init(jax.random.PRNGKey(0), CFG, abstract=True)
        # the builder asserted already; re-assert on the public bundle
        R.assert_nm_unsplit(bundle.state_shardings["master"], aparams,
                            mesh8, SP)

    @pytest.mark.parametrize("idx_bits", [4, 8])
    def test_resolved_serve_shardings_unsplit(self, mesh8, idx_bits):
        """Both stored index widths resolve group-safe serve shardings:
        the u4 plane's compact axis (bytes = offsets/2) must shard on
        multiples of N/2 bytes so no N:M group straddles a shard."""
        sh = spmd.serve_shardings(CFG, mesh8, SP, n_slots=4, max_len=32,
                                  packed=True, idx_bits=idx_bits)
        from repro.core import bdwp  # noqa: F401  (eligibility backs this)
        from repro.models import transformer_lm as T
        from repro.serve.packed_params import pack_tree_element
        aparams, _ = T.init(jax.random.PRNGKey(0), CFG, abstract=True)
        packed, _ = pack_tree_element(aparams, SP, idx_bits=idx_bits)
        R.assert_nm_unsplit(sh["pspecs"]["params"], packed, mesh8, SP)

    def test_rules_refuse_group_splitting_spec(self):
        """A 4-way 'model' shard of a K=16 grouped axis (m=8) would put
        4 rows per shard — the rules must replicate instead, and the
        assert must reject a hand-built splitting spec."""
        mesh = spmd.make_spmd_mesh("data=2,model=4")
        w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
        specs = {"blk": {"w": ("mlp", "embed")}}
        params = {"blk": {"w": w}}
        out = R.nm_params_pspecs(specs, R.TRAIN_RULES, params, mesh, SP)
        assert out["blk"]["w"][0] is None  # "model" dropped: would split
        with pytest.raises(AssertionError, match="group split"):
            R.assert_nm_unsplit({"blk": {"w": P("model", None)}},
                                params, mesh, SP)


class TestMoEPregenSPMD:
    """Bare-array MoE pregen under expert-parallel SPMD: the group
    guard keeps N:M groups and whole experts per shard, the census holds
    on the forced 8-device mesh, and legacy-vs-pregen stays bitwise."""

    SP4 = SparsityConfig(n=2, m=4, method="bdwp")

    def _moe_cfg(self):
        # the one MoE rig, shared with the solo-mesh suite: same model,
        # same E != m census property, one place to tune
        from test_pregen import MOE_CFG
        return MOE_CFG

    def test_expert_stack_group_split_refused(self):
        """A mesh axis that would cut an M-group along an expert stack's
        contraction axis must be dropped by the rules and rejected by
        the assert; an uneven expert split is rejected too (an expert's
        matrix never straddles devices)."""
        mesh = spmd.make_spmd_mesh("data=2,model=4")
        w = jax.ShapeDtypeStruct((8, 16, 16), jnp.float32)
        specs = {"moe": {"w_gate": ("expert", "embed", "mlp")}}
        params = {"moe": {"w_gate": w}}
        out = R.nm_params_pspecs(specs, R.TRAIN_RULES, params, mesh, SP)
        # expert-parallel over "model" is fine (whole experts per shard)
        assert out["moe"]["w_gate"][0] == "model"
        # ..."embed"->"data" on K: 8 rows/shard, still a multiple of m=8
        assert out["moe"]["w_gate"][1] == "data"
        with pytest.raises(AssertionError, match="group split"):
            R.assert_nm_unsplit({"moe": {"w_gate": P(None, "model", None)}},
                                params, mesh, SP)
        w6 = {"moe": {"w_gate": jax.ShapeDtypeStruct((6, 16, 16),
                                                     jnp.float32)}}
        with pytest.raises(AssertionError, match="group split"):
            R.assert_nm_unsplit({"moe": {"w_gate": P("model", None, None)}},
                                w6, mesh, SP)
        # the rules themselves refuse the K-split: a 4-way "model" shard
        # of K=16 (m=8) falls back to replicated
        specs_k = {"moe": {"w_gate": ("expert", "mlp", None)}}
        out_k = R.nm_params_pspecs(specs_k, R.SERVE_BATCH_RULES, params,
                                   mesh, SP)
        assert out_k["moe"]["w_gate"][1] is None

    def test_moe_resolved_shardings_unsplit(self, mesh8):
        cfg = self._moe_cfg()
        bundle = ST.build_lm_train(cfg, mesh8, self.SP4, OPT, donate=False)
        from repro.models import transformer_lm as T
        aparams, _ = T.init(jax.random.PRNGKey(0), cfg, abstract=True)
        R.assert_nm_unsplit(bundle.state_shardings["master"], aparams,
                            mesh8, self.SP4)

    def test_moe_census_and_bitwise_ab_on_mesh8(self, mesh8):
        """Acceptance: on the forced 8-device expert-parallel mesh the
        jitted MoE train step still derives exactly one mask per
        prunable param, and (mask-stable weights) the pregen trajectory
        reproduces the legacy one bitwise on the same mesh."""
        from repro.core import bdwp
        from repro.launch.hlo_cost import count_mask_ops
        from test_pregen import _stabilize_masks

        cfg = self._moe_cfg()
        sp = self.SP4
        opt = sgd.SGDConfig(lr=5e-4, warmup_steps=0, total_steps=100,
                            min_lr_frac=1.0)

        def structs(t):
            return jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)

        def run(pregen, steps=2):
            bundle = ST.build_lm_train(cfg, mesh8, sp, opt, donate=False,
                                       pregen=pregen)
            state = ST.init_train_state(jax.random.PRNGKey(0), cfg,
                                        sp_cfg=sp, pregen=pregen)
            state["master"] = _stabilize_masks(state["master"], sp)
            if pregen:
                state["compute"] = sgd.pregen_tree(state["master"], sp)
            state = jax.device_put(state, bundle.state_shardings)
            sh = {k: NamedSharding(mesh8, ps)
                  for k, ps in bundle.input_pspecs.items()}
            stream = D.lm_stream(cfg.vocab, 4, 32, shardings=sh, seed=0)
            losses = []
            for i, (_, b) in enumerate(stream):
                if i >= steps:
                    break
                state, metrics = bundle.step_fn(state, b)
                losses.append(float(metrics["loss"]))
            return bundle, state, losses

        bundle, s_pre, l_pre = run(True)
        state0 = ST.init_train_state(jax.random.PRNGKey(0), cfg, sp_cfg=sp)
        names = sgd._names_of(state0["master"])
        n_sites = sum(
            bdwp.pregen_site(n, sgd._logical_shape(n, w.shape)[0], sp)
            for n, w in zip(names, jax.tree.leaves(state0["master"])))
        batch = {"tokens": jnp.zeros((4, 32), jnp.int32),
                 "labels": jnp.zeros((4, 32), jnp.int32)}
        count = count_mask_ops(bundle.step_fn, structs(
            jax.device_put(state0, bundle.state_shardings)),
            structs(batch), nm=(sp.n, sp.m))
        assert count == n_sites > 0

        _, s_leg, l_leg = run(False)
        assert l_pre == l_leg
        for a, b in zip(_host(s_pre["master"]), _host(s_leg["master"])):
            np.testing.assert_array_equal(a, b)


class TestCheckpointReshard:
    def _state_and_bundle(self, mesh):
        bundle = ST.build_lm_train(CFG, mesh, SP, OPT, donate=False)
        state = ST.init_train_state(jax.random.PRNGKey(7), CFG, sp_cfg=SP)
        return bundle, jax.device_put(state, bundle.state_shardings)

    @pytest.mark.parametrize("direction", ["8to1", "1to8"])
    def test_save_restore_across_meshes(self, mesh8, mesh1, tmp_path,
                                        direction):
        src, dst = (mesh8, mesh1) if direction == "8to1" else (mesh1, mesh8)
        _, state = self._state_and_bundle(src)
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(0, state, blocking=True)

        dst_bundle, like = self._state_and_bundle(dst)
        restored = mgr.restore(like, shardings=dst_bundle.state_shardings)
        for a, b in zip(_host(restored), _host(state)):
            np.testing.assert_array_equal(a, b)
        # every restored leaf actually lives under the dst mesh sharding
        flat_r = jax.tree.leaves(restored)
        flat_sh = jax.tree.leaves(dst_bundle.state_shardings)
        for arr, sh in zip(flat_r, flat_sh):
            assert arr.sharding.is_equivalent_to(sh, arr.ndim)
