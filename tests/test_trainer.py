"""Fault-tolerance stack tests: checkpoint atomicity/retention/elastic
restore, straggler detection, heartbeat, auto-resume, full trainer loop."""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager
from repro.train.fault import Heartbeat, StragglerMonitor, recover_or_init

jax.config.update("jax_platform_name", "cpu")


def _state(step=0, scale=1.0):
    return {
        "master": {"w": jnp.full((4, 8), scale, jnp.float32),
                   "b": jnp.arange(8, dtype=jnp.float32) * scale},
        "momentum": {"w": jnp.zeros((4, 8)), "b": jnp.zeros((8,))},
        "step": jnp.asarray(step, jnp.int32),
    }


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        st = _state(step=7, scale=3.5)
        mgr.save(7, st, blocking=True)
        out = mgr.restore(_state())
        assert int(out["step"]) == 7
        np.testing.assert_array_equal(np.asarray(out["master"]["w"]),
                                      np.asarray(st["master"]["w"]))

    def test_async_save_commits(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(3, _state(3))
        mgr.wait()
        assert mgr.latest_step() == 3
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))

    def test_retention_keeps_newest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _state(s), blocking=True)
        assert mgr.all_steps() == [3, 4]

    def test_torn_write_never_visible(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        # a stale .tmp from a crashed writer must not count as a checkpoint
        os.makedirs(tmp_path / "step_00000099.tmp")
        assert mgr.latest_step() is None

    def test_structure_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state(), blocking=True)
        with pytest.raises(ValueError):
            mgr.restore({"only": jnp.zeros(3)})

    def test_elastic_restore_under_new_shardings(self, tmp_path):
        """Checkpoint is mesh-agnostic: restore re-device_puts under the
        current mesh's shardings (1-device container: identity mesh)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        mgr = CheckpointManager(str(tmp_path))
        st = _state(5)
        mgr.save(5, st, blocking=True)
        mesh = jax.make_mesh((1,), ("data",))
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), st)
        out = mgr.restore(_state(), shardings=sh)
        assert out["master"]["w"].sharding == NamedSharding(mesh, P())


class TestRecoverOrInit:
    def test_fresh_when_no_checkpoint(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        st, step = recover_or_init(mgr, lambda: _state(0))
        assert step == 0 and int(st["step"]) == 0

    def test_resumes_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(11, _state(11, scale=2.0), blocking=True)
        st, step = recover_or_init(mgr, lambda: _state(0))
        assert step == 11 and float(st["master"]["w"][0, 0]) == 2.0


class TestStraggler:
    def test_flags_slow_step(self):
        mon = StragglerMonitor(threshold=2.0, warmup=2)
        for i in range(5):
            assert not mon.record(i, 0.1)
        assert mon.record(5, 0.5)   # 5x the EWMA mean
        assert not mon.record(6, 0.1)

    def test_warmup_never_flags(self):
        mon = StragglerMonitor(threshold=1.01, warmup=3)
        assert not mon.record(0, 10.0)
        assert not mon.record(1, 0.0001)

    def test_compile_step_never_seeds_mean(self):
        """Regression: step 0 carries jit compilation (here 100x a
        steady step).  Seeding the EWMA from it poisoned the mean so an
        early real straggler sailed under ``threshold x mean`` — warmup
        samples must be DISCARDED, with the mean seeded from the first
        post-warmup sample."""
        mon = StragglerMonitor(threshold=2.0, warmup=1)
        assert not mon.record(0, 10.0)     # compile-laden: discarded
        assert not mon.record(1, 0.1)      # seeds the mean
        assert mon.mean == pytest.approx(0.1)
        assert mon.record(2, 0.3)          # 3x the mean: flagged NOW
        assert mon.flagged == [(2, 0.3, pytest.approx(0.1))]
        # the straggler did not poison the mean either
        assert mon.mean == pytest.approx(0.1)

    def test_fewer_samples_than_warmup_never_seeds_the_mean(self):
        """Edge: a run killed (or a monitor queried) before ``warmup``
        samples arrive.  Every sample so far was discarded, so the EWMA
        must still be unseeded and nothing may have flagged — a mean
        accidentally seeded from a discarded warmup sample would poison
        every comparison after the restart."""
        mon = StragglerMonitor(threshold=1.01, warmup=5)
        for step, secs in enumerate((30.0, 0.001, 12.0, 0.5)):
            assert not mon.record(step, secs)   # 4 < warmup: all discarded
        assert mon.mean is None
        assert mon.flagged == []
        assert mon.count == 4
        # the first post-warmup sample seeds; the one after it compares
        assert not mon.record(4, 9.9)           # 5th: last warmup sample
        assert not mon.record(5, 0.2)           # seeds mean = 0.2
        assert mon.mean == pytest.approx(0.2)
        assert mon.record(6, 0.5)               # 2.5x: flagged


class TestHeartbeat:
    def test_beat_and_staleness(self, tmp_path):
        hb = Heartbeat(str(tmp_path / "hb.json"))
        hb.beat(3, loss=1.5)
        assert not hb.is_stale(60.0)
        data = json.load(open(tmp_path / "hb.json"))
        assert data["step"] == 3
        assert hb.age() < 5.0

    def test_two_writers_never_collide(self, tmp_path, monkeypatch):
        """Regression: during a watchdog restart the old and new process
        briefly both beat() the same path.  With a shared ``path +
        ".tmp"`` scratch name their write/replace pairs interleave — the
        loser's os.replace finds its tmp already consumed.  The barrier
        parks both writers between write and replace to force exactly
        that overlap; per-writer scratch names must survive it."""
        import threading

        from repro.train import fault as F

        path = str(tmp_path / "hb.json")
        a, b = Heartbeat(path), Heartbeat(path)
        assert a._tmp != b._tmp  # unique scratch per writer

        bar = threading.Barrier(2)
        real_dump = json.dump

        def stalling_dump(obj, f, **kw):
            real_dump(obj, f, **kw)
            bar.wait(timeout=10)  # both tmps written, neither replaced

        monkeypatch.setattr(F.json, "dump", stalling_dump)
        errors = []

        def beat(hb, step):
            try:
                hb.beat(step, loss=0.5)
            except Exception as e:  # pre-fix: FileNotFoundError here
                errors.append(e)

        threads = [threading.Thread(target=beat, args=(hb, s))
                   for hb, s in ((a, 1), (b, 2))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=10)
        assert errors == []
        data = json.load(open(path))  # one COMPLETE payload won
        assert data["step"] in (1, 2) and data["loss"] == 0.5

    def test_watchdog_mid_write_sees_only_committed_payloads(
            self, tmp_path, monkeypatch):
        """Edge: the watchdog fires WHILE a beat() is between write and
        replace.  The scratch file exists with a (possibly partial)
        payload, but ``path`` still holds the previous commit — age()
        must keep reading that committed payload (fresh, parseable) and
        never the in-flight scratch.  Before any commit at all, the same
        mid-write watchdog poll must report stale."""
        import threading

        from repro.train import fault as F

        path = str(tmp_path / "hb.json")
        hb = Heartbeat(path)

        in_write = threading.Event()
        release = threading.Event()
        real_dump = json.dump

        def stalling_dump(obj, f, **kw):
            real_dump(obj, f, **kw)
            in_write.set()
            assert release.wait(timeout=10)  # park before os.replace

        monkeypatch.setattr(F.json, "dump", stalling_dump)

        # -- no commit yet: watchdog during the very first write --------
        t = threading.Thread(target=hb.beat, args=(1,))
        t.start()
        assert in_write.wait(timeout=10)
        assert hb.age() is None           # nothing committed to read
        assert hb.is_stale(60.0)          # watchdog restarts: correct
        release.set()
        t.join(timeout=10)
        assert json.load(open(path))["step"] == 1

        # -- committed payload present: watchdog during the next write --
        in_write.clear()
        release.clear()
        t = threading.Thread(target=hb.beat, args=(2,), kwargs={"loss": 9.0})
        t.start()
        assert in_write.wait(timeout=10)
        age = hb.age()                    # reads the step-1 commit
        assert age is not None and age < 5.0
        assert not hb.is_stale(60.0)      # no spurious restart mid-write
        assert json.load(open(path))["step"] == 1
        release.set()
        t.join(timeout=10)
        data = json.load(open(path))      # step-2 commit landed whole
        assert data["step"] == 2 and data["loss"] == 9.0


class TestTrainerLoop:
    def test_fit_runs_checkpoints_and_history(self, tmp_path):
        from repro.configs import get_arch
        from repro.core.sparsity import SparsityConfig
        from repro.data import synthetic as D
        from repro.launch.mesh import make_host_mesh
        from repro.optim import sgd
        from repro.train import step as ST
        from repro.train import trainer as TR

        arch = get_arch("qwen3-8b")
        mesh = make_host_mesh()
        sp = SparsityConfig(n=2, m=8, method="bdwp")
        bundle = ST.build_lm_train(arch.smoke, mesh, sp,
                                   sgd.SGDConfig(total_steps=6))
        state = jax.device_put(
            ST.init_train_state(jax.random.PRNGKey(0), arch.smoke, sp_cfg=sp),
            bundle.state_shardings)
        tcfg = TR.TrainerConfig(total_steps=6, ckpt_every=3, log_every=100,
                                ckpt_dir=str(tmp_path))
        stream = D.lm_stream(arch.smoke.vocab, 2, 32)
        state, hist = TR.fit(bundle, state, stream, tcfg,
                             log_fn=lambda *_: None)
        assert len(hist) == 6
        assert all(np.isfinite(h["loss"]) for h in hist)
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.latest_step() == 6

    def test_fit_no_duplicate_save_on_aligned_final_step(self, tmp_path,
                                                         monkeypatch):
        """Regression: with total_steps % ckpt_every == 0 the loop's
        last periodic save and the post-loop "final snapshot" both
        targeted the SAME step — the blocking re-save raced the still-
        async writer on one step_XXXX.tmp.  Each step must be saved at
        most once; the final step must still be committed on disk."""
        from repro.configs import get_arch
        from repro.core.sparsity import SparsityConfig
        from repro.data import synthetic as D
        from repro.launch.mesh import make_host_mesh
        from repro.optim import sgd
        from repro.train import step as ST
        from repro.train import trainer as TR

        arch = get_arch("qwen3-8b")
        mesh = make_host_mesh()
        sp = SparsityConfig(n=2, m=8, method="bdwp")
        bundle = ST.build_lm_train(arch.smoke, mesh, sp,
                                   sgd.SGDConfig(total_steps=4))
        state = jax.device_put(
            ST.init_train_state(jax.random.PRNGKey(0), arch.smoke, sp_cfg=sp),
            bundle.state_shardings)

        calls = []
        orig_save = CheckpointManager.save

        def spy(self, step, st, blocking=False):
            calls.append(step)
            return orig_save(self, step, st, blocking=blocking)

        monkeypatch.setattr(CheckpointManager, "save", spy)
        tcfg = TR.TrainerConfig(total_steps=4, ckpt_every=2, log_every=100,
                                ckpt_dir=str(tmp_path))
        TR.fit(bundle, state, D.lm_stream(arch.smoke.vocab, 2, 32), tcfg,
               log_fn=lambda *_: None)
        # pre-fix: [2, 4, 4] — step 4 written twice, async + blocking
        assert calls == [2, 4]
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.latest_step() == 4  # the async save still committed
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))

    def test_fit_resume_keys_off_state_step(self, tmp_path):
        """Auto-resume bookkeeping: after a restart the data iterator
        begins at 0 while the restored state step does not.  Checkpoint
        keys must come from state["step"] (the old iterator-keyed saves
        collided/regressed and misfired the save guard), the stale
        iterator must fast-forward, and every saved checkpoint's
        directory key must equal its internal step."""
        from repro.configs import get_arch
        from repro.core.sparsity import SparsityConfig
        from repro.data import synthetic as D
        from repro.launch.mesh import make_host_mesh
        from repro.optim import sgd
        from repro.train import step as ST
        from repro.train import trainer as TR

        arch = get_arch("qwen3-8b")
        mesh = make_host_mesh()
        sp = SparsityConfig(n=2, m=8, method="bdwp")
        bundle = ST.build_lm_train(arch.smoke, mesh, sp,
                                   sgd.SGDConfig(total_steps=8))
        state = jax.device_put(
            ST.init_train_state(jax.random.PRNGKey(0), arch.smoke, sp_cfg=sp),
            bundle.state_shardings)
        mgr = CheckpointManager(str(tmp_path), keep=0)

        tcfg = TR.TrainerConfig(total_steps=4, ckpt_every=2, log_every=100,
                                ckpt_dir=str(tmp_path))
        state, hist1 = TR.fit(bundle, state, D.lm_stream(arch.smoke.vocab, 2, 32),
                              tcfg, log_fn=lambda *_: None)
        assert [h["step"] for h in hist1] == [0, 1, 2, 3]
        assert mgr.all_steps() == [2, 4]

        # crash + restart: restore newest, hand fit a FRESH iterator (0-based)
        restored = mgr.restore(jax.tree.map(jnp.zeros_like, state),
                               shardings=bundle.state_shardings)
        assert int(restored["step"]) == 4
        tcfg2 = TR.TrainerConfig(total_steps=8, ckpt_every=2, log_every=100,
                                 ckpt_dir=str(tmp_path))
        state2, hist2 = TR.fit(bundle, restored,
                               D.lm_stream(arch.smoke.vocab, 2, 32),
                               tcfg2, log_fn=lambda *_: None)
        # resumed history continues at the optimizer step, no regression
        assert [h["step"] for h in hist2] == [4, 5, 6, 7]
        assert mgr.all_steps() == [4, 6, 8]  # keep=3 retention pruned 2
        # every checkpoint's directory key equals its internal step
        like = jax.tree.map(jnp.zeros_like, state)
        for s in mgr.all_steps():
            ck = mgr.restore(like, step=s, shardings=bundle.state_shardings)
            assert int(ck["step"]) == s
        # fast-forward consumed the stream at the right offset: a run fed
        # a correctly-offset stream lands on the identical final state
        restored_b = mgr.restore(jax.tree.map(jnp.zeros_like, state),
                                 step=4, shardings=bundle.state_shardings)
        state3, _ = TR.fit(bundle, restored_b,
                           D.lm_stream(arch.smoke.vocab, 2, 32, start=4),
                           tcfg2, log_fn=lambda *_: None)
        for a, b in zip(jax.tree.leaves(state2["master"]),
                        jax.tree.leaves(state3["master"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
