"""Docs lint: every repo path referenced in the docs must exist.

  python tools/docs_lint.py            # from the repo root
  python tools/docs_lint.py --list     # show every checked reference

Three checks, all blocking in CI (the `test` job) and wrapped as
tier-1 tests by tests/test_docs_lint.py:

  1. **Path references.**  Every token that looks like a repo path —
     ``src/...``, ``tests/...``, ``benchmarks/...``, ``tools/...``,
     ``docs/...``, ``results/...`` — appearing anywhere in README.md,
     ROADMAP.md, EXPERIMENTS.md, or docs/*.md must exist on disk
     (file or directory).  Docs that name dead modules are
     worse than no docs: they send the reader to a file that was
     renamed three refactors ago.
  2. **Intra-doc links.**  Every relative markdown link target
     ``[text](target)`` in those files must resolve (fragments are
     split off; http/https/mailto links are ignored).
  3. **Bench fields.**  Every field named in the first column of a
     ``## `results/BENCH_X.json` …`` schema table (docs/benchmarks.md;
     ``results/NMLINT.json`` gets the same treatment) must exist in
     the committed ``results/BENCH_X.json`` or its
     ``benchmarks/baselines/`` baseline.  Field tokens support
     ``{a,b}`` brace groups, ``*`` wildcards, ``<site>`` placeholders
     (= wildcard segment), ``loads[]`` list markers, and leading-dot
     continuations (``.pregen_packed`` after ``mask_ops.pregen``).
     A documented field nobody emits is schema fiction.

Tokens containing glob characters (``*``, ``?``) are skipped — bench
docs legitimately reference artifact patterns like
``results/dryrun/*.json``.  A path ending in ``/`` must be a
directory.
"""

from __future__ import annotations

import argparse
import fnmatch
import glob
import json
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# documents under lint.  CHANGES.md is deliberately NOT here: it is an
# append-only history log, and "removed results/foo.py" entries
# legitimately name files that no longer exist.
DOC_GLOBS = ("README.md", "ROADMAP.md", "EXPERIMENTS.md", "docs/*.md")

# top-level prefixes whose path-like mentions must exist on disk
PREFIXES = ("src", "tests", "benchmarks", "tools", "docs", "results")

_PATH_RE = re.compile(
    r"(?<![\w./-])(?:%s)/[\w./*?-]*[\w*?]" % "|".join(PREFIXES))
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

# bench-schema tables: "## `results/BENCH_X.json` — `benchmarks/x.py`";
# results/NMLINT.json (the nmlint report) documents its schema the same
# way, so its table is field-validated too
_BENCH_SECTION_RE = re.compile(
    r"^##\s+`results/((?:BENCH_\w+|NMLINT)\.json)`")
_TICK_RE = re.compile(r"`([^`]+)`")


def _docs() -> list:
    out = []
    for pat in DOC_GLOBS:
        out.extend(sorted(glob.glob(os.path.join(ROOT, pat))))
    return out


def _exists(path: str) -> bool:
    full = os.path.join(ROOT, path)
    if path.endswith("/"):
        return os.path.isdir(full)
    if os.path.exists(full):
        return True
    # module.attr notation ("tests/conftest.require_or_skip"): accept
    # when stripping the attribute leaves a live python module
    base = path.rsplit(".", 1)[0]
    return os.path.exists(os.path.join(ROOT, base + ".py"))


def check_doc(doc: str, show: bool = False) -> list:
    rel_doc = os.path.relpath(doc, ROOT)
    with open(doc) as f:
        text = f.read()
    failures = []

    refs = set()
    for m in _PATH_RE.finditer(text):
        tok = m.group(0).rstrip(".,;:")
        if "*" in tok or "?" in tok:
            continue  # artifact patterns like results/dryrun/*.json
        refs.add(tok)
    for tok in sorted(refs):
        ok = _exists(tok)
        if show:
            print(f"  [{'ok' if ok else 'MISSING'}] {rel_doc}: {tok}")
        if not ok:
            failures.append(f"{rel_doc}: references {tok} — not on disk")

    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        full = os.path.normpath(os.path.join(os.path.dirname(doc), path))
        if (doc.startswith(ROOT + os.sep)
                and not (full + os.sep).startswith(ROOT + os.sep)):
            continue  # escapes the repo (GitHub badge URLs) — unverifiable
        ok = os.path.exists(full)
        if show:
            print(f"  [{'ok' if ok else 'BROKEN'}] {rel_doc}: link "
                  f"-> {target}")
        if not ok:
            failures.append(f"{rel_doc}: link ({target}) does not resolve")
    return failures


# ---------------------------------------------------------------------------
# Check 3: bench-schema tables name only fields the benches actually emit
# ---------------------------------------------------------------------------


def _flatten_keys(obj, prefix: str = "") -> set:
    """Dotted paths of every node in a JSON tree (dicts recursed,
    lists/scalars are leaves; intermediate dict paths included)."""
    keys = set()
    if isinstance(obj, dict):
        for k, v in obj.items():
            path = f"{prefix}.{k}" if prefix else str(k)
            keys.add(path)
            keys |= _flatten_keys(v, path)
    return keys


def _expand_field(tok: str, prev_prefix: str) -> list:
    """One backticked field token -> fnmatch patterns.

    Grammar (docs/benchmarks.md convention): ``{a,b}`` brace groups
    expand, ``<site>`` placeholders become a ``*`` segment, ``x[]``
    marks a list field (checked as ``x``), and a leading dot continues
    the previous token's prefix (``.pregen_packed`` after
    ``mask_ops.pregen`` means ``mask_ops.pregen_packed``).
    """
    tok = tok.strip().rstrip(",")
    if tok.endswith("[]"):
        tok = tok[:-2]
    if tok.startswith("."):
        tok = prev_prefix + tok if prev_prefix else tok[1:]
    pats = [tok]
    while any("{" in p for p in pats):
        out = []
        for p in pats:
            m = re.search(r"\{([^{}]*)\}", p)
            if not m:
                out.append(p)
                continue
            for alt in m.group(1).split(","):
                out.append(p[:m.start()] + alt.strip() + p[m.end():])
        pats = out
    return [re.sub(r"<[^<>\s]+>", "*", p) for p in pats]


def _bench_keys(bench_file: str):
    """Union of flattened keys of the committed fresh result and its
    baseline (a field may live in either) -> (keys, sources) or
    (None, []) when neither file is committed."""
    keys, sources = set(), []
    for rel in (os.path.join("results", bench_file),
                os.path.join("benchmarks", "baselines", bench_file)):
        full = os.path.join(ROOT, rel)
        if os.path.exists(full):
            with open(full) as f:
                keys |= _flatten_keys(json.load(f))
            sources.append(rel)
    return (keys, sources) if sources else (None, [])


def check_bench_fields(doc: str, show: bool = False) -> list:
    """Validate every first-column field of each BENCH schema table in
    ``doc`` against the committed result/baseline JSONs."""
    rel_doc = os.path.relpath(doc, ROOT)
    with open(doc) as f:
        lines = f.read().splitlines()
    failures = []
    bench_file, keys, prev_prefix = None, None, ""
    for line in lines:
        m = _BENCH_SECTION_RE.match(line)
        if m:
            bench_file = m.group(1)
            keys, sources = _bench_keys(bench_file)
            prev_prefix = ""
            if keys is None:
                failures.append(
                    f"{rel_doc}: documents {bench_file} but neither "
                    f"results/ nor benchmarks/baselines/ commits it")
                bench_file = None
            continue
        if line.startswith("##"):
            bench_file = None  # left the schema section
            continue
        if bench_file is None or not line.lstrip().startswith("|"):
            continue
        first_cell = line.lstrip().lstrip("|").split("|", 1)[0]
        for tok in _TICK_RE.findall(first_cell):
            pats = _expand_field(tok, prev_prefix)
            prev_prefix = pats[0].rsplit(".", 1)[0] if "." in pats[0] else ""
            for pat in pats:
                ok = (bool(fnmatch.filter(keys, pat)) if "*" in pat
                      else pat in keys)
                if show:
                    print(f"  [{'ok' if ok else 'MISSING'}] {rel_doc}: "
                          f"{bench_file} field {pat}")
                if not ok:
                    failures.append(
                        f"{rel_doc}: documents field `{pat}` of "
                        f"{bench_file} — no committed result or baseline "
                        f"carries it")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--list", action="store_true",
                    help="print every checked reference, not just failures")
    args = ap.parse_args(argv)

    docs = _docs()
    if not docs:
        print("docs_lint: no documents found — wrong working tree?")
        return 1
    failures = []
    for doc in docs:
        failures.extend(check_doc(doc, show=args.list))
        failures.extend(check_bench_fields(doc, show=args.list))
    for f in failures:
        print(f"[FAIL] {f}")
    n_docs = len(docs)
    if failures:
        print(f"\ndocs_lint: {len(failures)} dead reference(s) across "
              f"{n_docs} documents")
        return 1
    print(f"docs_lint: {n_docs} documents clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
