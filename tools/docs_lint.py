"""Docs lint: every repo path referenced in the docs must exist.

  python tools/docs_lint.py            # from the repo root
  python tools/docs_lint.py --list     # show every checked reference

Two checks, both blocking in CI (the `test` job) and wrapped as a
tier-1 test by tests/test_docs_lint.py:

  1. **Path references.**  Every token that looks like a repo path —
     ``src/...``, ``tests/...``, ``benchmarks/...``, ``tools/...``,
     ``docs/...``, ``results/...`` — appearing anywhere in README.md,
     ROADMAP.md, EXPERIMENTS.md, or docs/*.md must exist on disk
     (file or directory).  Docs that name dead modules are
     worse than no docs: they send the reader to a file that was
     renamed three refactors ago.
  2. **Intra-doc links.**  Every relative markdown link target
     ``[text](target)`` in those files must resolve (fragments are
     split off; http/https/mailto links are ignored).

Tokens containing glob characters (``*``, ``?``) are skipped — bench
docs legitimately reference artifact patterns like
``results/dryrun/*.json``.  A path ending in ``/`` must be a
directory.
"""

from __future__ import annotations

import argparse
import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# documents under lint.  CHANGES.md is deliberately NOT here: it is an
# append-only history log, and "removed results/foo.py" entries
# legitimately name files that no longer exist.
DOC_GLOBS = ("README.md", "ROADMAP.md", "EXPERIMENTS.md", "docs/*.md")

# top-level prefixes whose path-like mentions must exist on disk
PREFIXES = ("src", "tests", "benchmarks", "tools", "docs", "results")

_PATH_RE = re.compile(
    r"(?<![\w./-])(?:%s)/[\w./*?-]*[\w*?]" % "|".join(PREFIXES))
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _docs() -> list:
    out = []
    for pat in DOC_GLOBS:
        out.extend(sorted(glob.glob(os.path.join(ROOT, pat))))
    return out


def _exists(path: str) -> bool:
    full = os.path.join(ROOT, path)
    if path.endswith("/"):
        return os.path.isdir(full)
    if os.path.exists(full):
        return True
    # module.attr notation ("tests/conftest.require_or_skip"): accept
    # when stripping the attribute leaves a live python module
    base = path.rsplit(".", 1)[0]
    return os.path.exists(os.path.join(ROOT, base + ".py"))


def check_doc(doc: str, show: bool = False) -> list:
    rel_doc = os.path.relpath(doc, ROOT)
    with open(doc) as f:
        text = f.read()
    failures = []

    refs = set()
    for m in _PATH_RE.finditer(text):
        tok = m.group(0).rstrip(".,;:")
        if "*" in tok or "?" in tok:
            continue  # artifact patterns like results/dryrun/*.json
        refs.add(tok)
    for tok in sorted(refs):
        ok = _exists(tok)
        if show:
            print(f"  [{'ok' if ok else 'MISSING'}] {rel_doc}: {tok}")
        if not ok:
            failures.append(f"{rel_doc}: references {tok} — not on disk")

    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        full = os.path.normpath(os.path.join(os.path.dirname(doc), path))
        if (doc.startswith(ROOT + os.sep)
                and not (full + os.sep).startswith(ROOT + os.sep)):
            continue  # escapes the repo (GitHub badge URLs) — unverifiable
        ok = os.path.exists(full)
        if show:
            print(f"  [{'ok' if ok else 'BROKEN'}] {rel_doc}: link "
                  f"-> {target}")
        if not ok:
            failures.append(f"{rel_doc}: link ({target}) does not resolve")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--list", action="store_true",
                    help="print every checked reference, not just failures")
    args = ap.parse_args(argv)

    docs = _docs()
    if not docs:
        print("docs_lint: no documents found — wrong working tree?")
        return 1
    failures = []
    for doc in docs:
        failures.extend(check_doc(doc, show=args.list))
    for f in failures:
        print(f"[FAIL] {f}")
    n_docs = len(docs)
    if failures:
        print(f"\ndocs_lint: {len(failures)} dead reference(s) across "
              f"{n_docs} documents")
        return 1
    print(f"docs_lint: {n_docs} documents clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
