"""nmlint — repo-wide N:M invariant auditor (AST + jaxpr/HLO).

  python tools/nmlint.py                  # AST pass, report, exit!=0 on findings
  python tools/nmlint.py --strict         # same (explicit; the CI spelling)
  python tools/nmlint.py --graph          # + jaxpr/HLO audit, solo config matrix
  python tools/nmlint.py --numerics       # + NM3xx dtype-provenance family
  python tools/nmlint.py --buffers        # + NM4xx donation/dispatch family
  python tools/nmlint.py --graph --mesh8  # + compressed grad-sync on 8 forced
                                          #   CPU devices (forces them itself)
  python tools/nmlint.py --changed-only   # AST rules on git-changed files only
                                          #   (fast pre-commit; no report write)
  python tools/nmlint.py --selftest       # seed 1 violation/rule, all must fire
  python tools/nmlint.py --list-rules     # rule table (ID, kind, invariant)

--graph/--numerics/--buffers each enable one rule family over the same
config matrix; a case traces/compiles ONCE and every requested family
reads the shared artifact.  The AST-stage rules (NM1xx, NM402, NM404)
always run.  Every matrix run rewrites results/NMLINT.json (schema v2)
— deterministic counts only, so the committed copy diffs empty while
the invariants hold.  Waivers: tools/nmlint_waivers.json (rule + path
glob + reason + expiry; an expired waiver is an NM001 finding).  Rules:
docs/analysis.md.  Wrapped into tier-1 by tests/test_nmlint.py; the
blocking CI job runs ``--strict --numerics --buffers --graph --mesh8``.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))


def _changed_repro_files() -> list:
    """src/repro/**.py files changed vs HEAD (staged, unstaged, or
    untracked) — the pre-commit scope."""
    prefix = os.path.join("src", "repro") + os.sep
    out = set()
    for cmd in (["git", "diff", "HEAD", "--name-only"],
                ["git", "ls-files", "--others", "--exclude-standard"]):
        res = subprocess.run(cmd, cwd=ROOT, capture_output=True, text=True)
        for line in res.stdout.splitlines():
            line = line.strip()
            if line.endswith(".py") and line.startswith(prefix.replace(
                    os.sep, "/")):
                path = os.path.join(ROOT, line)
                if os.path.exists(path):
                    out.add(path)
    return sorted(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on any unwaived finding (default "
                         "behavior; flag kept explicit for CI readability)")
    ap.add_argument("--graph", action="store_true",
                    help="run the NM2xx structure family over the config "
                         "matrix (traces + compiles real smoke models)")
    ap.add_argument("--numerics", action="store_true",
                    help="run the NM3xx dtype-provenance family over the "
                         "config matrix (implies running the matrix)")
    ap.add_argument("--buffers", action="store_true",
                    help="run the NM4xx donation/dispatch family over the "
                         "config matrix (implies running the matrix)")
    ap.add_argument("--mesh8", action="store_true",
                    help="add the mesh8 cases (forces 8 host devices; "
                         "implies --graph)")
    ap.add_argument("--changed-only", action="store_true",
                    help="AST rules over git-changed src/repro files only; "
                         "graph matrix skipped, no report written — the "
                         "fast pre-commit mode")
    ap.add_argument("--selftest", action="store_true",
                    help="seed one violation per rule; exit 0 iff every "
                         "rule fires")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--out", default=os.path.join(ROOT, "results",
                                                  "NMLINT.json"))
    ap.add_argument("--waivers", default=os.path.join(ROOT, "tools",
                                                      "nmlint_waivers.json"))
    args = ap.parse_args(argv)

    if args.mesh8:
        # must happen before anything touches the jax backend
        from repro.launch.spmd import force_host_devices
        force_host_devices(8)
        args.graph = True

    from repro.analysis import (
        RULES, apply_waivers, build_report, load_waivers, run_ast_pass,
        run_async_sync_pass, run_graph_audit, run_selftest,
        scanned_file_count, write_report,
    )

    if args.list_rules:
        for r in RULES:
            print(f"{r.id}  [{r.kind:5s}] {r.title}\n    {r.invariant}")
        return 0

    if args.selftest:
        ok, fired = run_selftest()
        for rule in sorted(fired):
            print(f"  [{'fired' if fired[rule] else 'SILENT'}] {rule}")
        if not ok:
            print("nmlint selftest: FAILED — a seeded violation did not "
                  "produce a finding; the auditor has gone blind")
            return 1
        print(f"nmlint selftest: all {len(fired)} rules fire on their "
              f"seeded violations")
        return 0

    waivers, expired = load_waivers(args.waivers)

    if args.changed_only:
        files = _changed_repro_files()
        findings = run_ast_pass(files=files) if files else []
        # serve/ may have changed callers of serve/fleet.py — the async
        # sync pass is whole-package and cheap, so always rerun it
        findings += run_async_sync_pass()
        findings = apply_waivers(findings, waivers) + expired
        unwaived = [f for f in findings if not f.waived]
        for f in findings:
            print(f"[{'warn' if f.waived else 'FAIL'}] {f}")
        # no report write: a partial scan must not clobber the committed
        # full-matrix results/NMLINT.json
        if unwaived:
            print(f"\nnmlint --changed-only: {len(unwaived)} finding(s) "
                  f"across {len(files)} changed file(s)")
            return 1
        print(f"nmlint --changed-only: clean — {len(files)} changed "
              f"file(s)")
        return 0

    findings = run_ast_pass() + run_async_sync_pass()
    findings = apply_waivers(findings, waivers) + expired

    families = []
    if args.graph:
        families.append("graph")
    if args.numerics:
        families.append("numerics")
    if args.buffers:
        families.append("buffers")

    graph_metrics, cases = {}, []
    if families:
        gfindings, graph_metrics = run_graph_audit(mesh8=args.mesh8,
                                                   families=families)
        findings += apply_waivers(gfindings, waivers)
        cases = list(graph_metrics)

    report = build_report(findings, graph_metrics, cases,
                          scanned_files=scanned_file_count(),
                          families_run=families)
    out = write_report(report, args.out)

    unwaived = [f for f in findings if not f.waived]
    for f in findings:
        print(f"[{'warn' if f.waived else 'FAIL'}] {f}")
    n_files = report["scanned_files"]
    suffix = (f" + {'/'.join(families)} audit over {len(cases)} case(s)"
              if cases else "")
    if unwaived:
        print(f"\nnmlint: {len(unwaived)} finding(s) "
              f"({len(findings) - len(unwaived)} waived) across {n_files} "
              f"files{suffix} — report: {os.path.relpath(out, ROOT)}")
        return 1
    print(f"nmlint: clean — {n_files} files{suffix}; report: "
          f"{os.path.relpath(out, ROOT)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
